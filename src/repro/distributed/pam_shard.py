"""Distributed PAMattention (paper Alg. 1 across devices) via shard_map.

Layout: KV caches sequence-sharded on the ``model`` mesh axis — each device
plays the role of one PIM site holding its KV partition. One decode step:

  local stage   : each device attends its own KV shard -> (O, m, l)
  merge stage   : exact online-softmax reduction across the axis —
                  m* = pmax(m);  O = psum(e^{m-m*} O);  l = psum(e^{m-m*} l)

The merge communicates H x (d + 2) floats per device — independent of
context length. A gather-based scheme would move the whole KV shard
(S_local x H_kv x d); this is the paper's "reduce communication" claim,
and the collective-bytes delta shows up directly in the dry-run roofline.

``sequence_sharded_decode_attn`` plugs straight into
``transformer.decode_step(decode_attn_fn=...)``.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat  # noqa: F401  (backfills jax.shard_map on 0.4)

from jax.sharding import Mesh, PartitionSpec as P


@functools.lru_cache(maxsize=None)
def decode_mesh(shard: int, *, axis: str = "model") -> Mesh:
    """The serving engine's 1-D decode mesh over the first ``shard``
    local XLA devices. Cached so every same-shard engine (and every
    replica group of the same size) shares ONE mesh object — which is
    what lets their jitted dispatches share the module-level compile
    caches."""
    devs = jax.devices()
    if len(devs) < shard:
        raise ValueError(
            f"shard={shard} needs {shard} local XLA devices but only "
            f"{len(devs)} present; on CPU relaunch under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{max(shard, 8)} (must be set before jax is imported)")
    return Mesh(np.asarray(devs[:shard]), (axis,))


def merge_collective_bytes(n_layers: int, n_heads: int, head_dim: int,
                           batch: int, *, smax: int = 0
                           ) -> tuple[int, int]:
    """Modeled per-device collective bytes of ONE sharded decode step.

    Returns ``(merge_bytes, mass_bytes)``: ``merge_bytes`` is the Alg. 1
    cross-shard reduction — ``pmax``/``psum`` of the ``(O, m, l)``
    triple, i.e. ``H x (d + 2)`` fp32 per layer per batch row —
    independent of context length (the paper's flat-communication
    claim). ``mass_bytes`` is the importance-mass psum that keeps the
    EMA/Alg. 2 state replicated — an observability side channel that IS
    linear in ``smax`` and is reported separately in benchmarks."""
    merge = n_layers * batch * n_heads * (head_dim + 2) * 4
    mass = n_layers * batch * smax * 4
    return merge, mass


def make_sequence_sharded_decode_attn(mesh: Mesh, *, axis: str = "model",
                                      dp=None):
    """Returns a decode_attn_fn (q, k_cache, v_cache, kv_lens) -> (out,
    mass) computing PAMattention with KV sequence-sharded over ``axis``.

    q: (B, H, dh) replicated over ``axis``; caches (B, Hkv, S, dh) sharded
    on S; kv_lens (B,). ``mass`` is returned sequence-sharded-consistent
    (global (B, S) array, sharded like the cache on its S axis).
    """

    def local_fn(q, k, v, kv_lens):
        # shapes here are PER-SHARD: k/v (B, Hkv, S_loc, dh)
        B, H, dh = q.shape
        Hkv, S_loc = k.shape[1], k.shape[2]
        rep = H // Hkv
        scale = 1.0 / math.sqrt(dh)
        shard = jax.lax.axis_index(axis)
        start = shard * S_loc
        pos = start + jnp.arange(S_loc)                    # global positions
        live = pos[None, :] < kv_lens[:, None]             # (B, S_loc)

        # grouped (GQA) form: NO jnp.repeat KV expansion — query heads are
        # contracted against their shared kv head directly
        qg = q.reshape(B, Hkv, rep, dh)
        s = jnp.einsum("bgrd,bgsd->bgrs", qg.astype(jnp.float32),
                       k.astype(jnp.float32)) * scale
        s = jnp.where(live[:, None, None, :], s, -jnp.inf)

        # ---- local partial (Alg. 1 Local_Attention) ----------------------
        m_loc = jnp.max(s, axis=-1)                        # (B, Hkv, rep)
        m_safe = jnp.where(jnp.isfinite(m_loc), m_loc, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(live[:, None, None, :], p, 0.0)
        l_loc = jnp.sum(p, axis=-1)
        o_loc = jnp.einsum("bgrs,bgsd->bgrd", p, v.astype(jnp.float32))

        # ---- inter-device reduction (Alg. 1 Reduction) --------------------
        m_star = jax.lax.pmax(m_loc, axis)
        m_star_safe = jnp.where(jnp.isfinite(m_star), m_star, 0.0)
        w = jnp.where(jnp.isfinite(m_loc),
                      jnp.exp(m_loc - m_star_safe), 0.0)   # (B, Hkv, rep)
        o = jax.lax.psum(w[..., None] * o_loc, axis)
        l = jax.lax.psum(w * l_loc, axis)
        l_safe = jnp.where(l > 0, l, 1.0)
        out = (o / l_safe[..., None]).reshape(B, H, dh).astype(q.dtype)

        # per-token mass on MY shard, normalized by the global (m*, l)
        p_norm = (p * w[..., None]) / l_safe[..., None]
        n_live = jax.lax.psum(jnp.sum(live, axis=-1), axis)  # (B,)
        mass = (jnp.mean(p_norm, axis=(1, 2))
                * n_live[:, None].astype(jnp.float32))
        return out, mass

    return jax.shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(dp), P(dp, None, axis, None), P(dp, None, axis, None),
                  P(dp)),
        out_specs=(P(dp), P(dp, axis)),
        check_vma=False,
    )


def fused_update_decode(q, k_cache, v_cache, k_new, v_new, kv_lens, *,
                        axis: str = "model"):
    """§Perf ``pam_shard_decode``: one shard_map doing BOTH the new-token
    cache write and PAMattention over the sequence-sharded cache.

    The baseline lets GSPMD lower ``cache.at[b, :, pos].set(new)`` on a
    sequence-sharded axis, which materializes a gather of the whole cache;
    here each shard applies the write only if ``pos`` falls in its range
    (a masked local dynamic-update), then computes its local partial and
    joins the exact psum merge. Uses the ambient abstract mesh.

    q: (B, H, dh); caches (B, Hkv, S, dh) sequence-sharded on ``axis``;
    k_new/v_new: (B, Hkv, dh); kv_lens: (B,) pre-append lengths.
    Returns (out, mass, k_cache, v_cache).
    """
    from repro.models import perf_flags
    mesh = perf_flags.abstract_mesh()
    B = q.shape[0]
    dp: tuple | None = tuple(a for a in mesh.axis_names
                             if a in ("pod", "data")) or None
    if dp is not None:
        dp_size = 1
        for a in dp:
            dp_size *= mesh.shape[a]
        if B % dp_size:
            dp = None

    def local(q, kc, vc, kn, vn, lens):
        Bl, H, dh = q.shape
        Hkv, S_loc = kc.shape[1], kc.shape[2]
        rep = H // Hkv
        scale = 1.0 / math.sqrt(dh)
        shard = jax.lax.axis_index(axis)
        start = shard * S_loc

        # ---- masked local cache write (the paper's intra-device mapping:
        # the owning bank group takes the token; everyone else no-ops) ----
        pos_local = lens - start
        in_range = (pos_local >= 0) & (pos_local < S_loc)
        safe = jnp.clip(pos_local, 0, S_loc - 1)
        bidx = jnp.arange(Bl)
        old_k = kc[bidx, :, safe]
        old_v = vc[bidx, :, safe]
        kc = kc.at[bidx, :, safe].set(
            jnp.where(in_range[:, None, None], kn, old_k))
        vc = vc.at[bidx, :, safe].set(
            jnp.where(in_range[:, None, None], vn, old_v))

        # ---- local partial + exact psum merge (Alg. 1) -------------------
        # grouped (GQA) form: NO jnp.repeat — the baseline materializes
        # rep x the KV shard; here queries are grouped per kv head instead
        live = (start + jnp.arange(S_loc))[None, :] < (lens + 1)[:, None]
        qg = q.reshape(Bl, Hkv, rep, dh)
        # bf16 operands read directly, fp32 accumulate: no cast copy of the
        # KV shard (iteration 3 of §Perf cell A)
        s = jnp.einsum("bgrd,bgsd->bgrs", qg, kc,
                       preferred_element_type=jnp.float32) * scale
        s = jnp.where(live[:, None, None, :], s, -jnp.inf)
        m_loc = jnp.max(s, axis=-1)                        # (B, Hkv, rep)
        m_safe = jnp.where(jnp.isfinite(m_loc), m_loc, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(live[:, None, None, :], p, 0.0)
        l_loc = jnp.sum(p, axis=-1)
        o_loc = jnp.einsum("bgrs,bgsd->bgrd", p, vc,
                           preferred_element_type=jnp.float32)

        m_star = jax.lax.pmax(m_loc, axis)
        m_star_safe = jnp.where(jnp.isfinite(m_star), m_star, 0.0)
        w = jnp.where(jnp.isfinite(m_loc),
                      jnp.exp(m_loc - m_star_safe), 0.0)
        o = jax.lax.psum(w[..., None] * o_loc, axis)
        l = jax.lax.psum(w * l_loc, axis)
        l_safe = jnp.where(l > 0, l, 1.0)
        out = (o / l_safe[..., None]).reshape(Bl, H, dh).astype(q.dtype)

        p_norm = (p * w[..., None]) / l_safe[..., None]    # (B,Hkv,rep,S)
        n_live = jax.lax.psum(jnp.sum(live, axis=-1), axis)
        mass = (jnp.mean(p_norm, axis=(1, 2))
                * n_live[:, None].astype(jnp.float32))
        return out, mass, kc, vc

    kv_spec = P(dp, None, axis, None)
    return jax.shard_map(
        local, mesh=mesh,
        in_specs=(P(dp), kv_spec, kv_spec, P(dp), P(dp), P(dp)),
        out_specs=(P(dp), P(dp, axis), kv_spec, kv_spec),
        check_vma=False,
    )(q, k_cache, v_cache, k_new, v_new, kv_lens)


def make_sharded_paged_decode_attn(mesh: Mesh, hot_mask, paged_mask,
                                   block_table, block_live, *,
                                   axis: str = "model", scale=None):
    """The PR 10 tentpole attention: hot-ring ⊕ paged partials with the
    ring's SLOT axis and the pool's BLOCK axis sharded over ``axis``.

    Drop-in twin of ``pam_manager.make_paged_decode_attn`` — returns a
    ``decode_attn_fn(q, k_cache, v_cache, pk, pv, kv_lens) -> (out,
    mass)`` for ``transformer.decode_step`` — but the per-layer ring
    ``(B, Hkv, W, dh)`` is split on W and the per-layer pool
    ``(NB+1, bs, Hkv, dh)`` on its physical-block axis. Each shard:

      * owns ring slots ``[r·W_loc, (r+1)·W_loc)`` — its slice of the
        rotated position map (``ring_position_map(start=...)``) maps
        them to absolute positions, and since an in-window position
        lives in exactly one global slot, hot contributions PARTITION
        across shards;
      * owns physical blocks ``[r·NB_loc, (r+1)·NB_loc)`` — the GLOBAL
        block table is an explicit replicated operand (tables survive
        distribution unchanged, the PagedAttention property) and
        non-local entries are masked to the merge identity
        (``ops.paged_decode_attention_partial(block_offset=...)``,
        Pallas table-walk on TPU, jnp gather elsewhere);
      * merges its hot+paged partials locally (exact Alg. 1), then
        joins the cross-shard ``pmax``/``psum`` of ``(O, m, l)`` —
        ``H x (d+2)`` fp32 per device, independent of context length.

    ``out`` and ``mass`` come back REPLICATED (the mass is psum-merged
    onto absolute coordinates), so the importance-EMA/Alg. 2 state and
    the sampling path downstream are untouched by sharding — which is
    why sharded token streams are bit-exact twins of unsharded ones.

    The masks/table are traced per-step values, and shard_map forbids
    closing over traced arrays — they ride as explicit replicated
    operands instead.
    """
    from repro.core import online_softmax as osm
    from repro.core.pam_interface import paged_gather_logical
    from repro.kernels import ops
    from repro.kernels.flash_decode import (ring_gather_mask,
                                            ring_position_map)
    nshards = mesh.shape[axis]

    def local_fn(q, kc, vc, pk, pv, bt, bl, hot_mask, paged_mask,
                 kv_lens):
        B, H, d = q.shape
        Hkv, W_loc = kc.shape[1], kc.shape[2]
        NB_loc, bs = pk.shape[0], pk.shape[1]
        Smax = hot_mask.shape[1]
        rep = H // Hkv
        sc = scale if scale is not None else 1.0 / (d ** 0.5)
        r = jax.lax.axis_index(axis)
        live_len = jnp.arange(Smax)[None, :] < kv_lens[:, None]
        hot = hot_mask & live_len
        pgd = paged_mask & live_len

        # ---- hot partial over MY ring slots ---------------------------
        ring_pos, ring_valid = ring_position_map(
            kv_lens, W_loc * nshards, start=r * W_loc, size=W_loc)
        hot_ring = ring_gather_mask(hot, ring_pos, ring_valid)
        s_ring = ops._grouped_scores(q, kc, sc)     # (B, Hkv, rep, W_loc)
        part = ops._grouped_partial_from_scores(s_ring, vc, hot_ring)

        # ---- paged partial over MY physical blocks --------------------
        lo = r * NB_loc
        part_pgd = ops.paged_decode_attention_partial(
            q, pk, pv, bt, pgd, block_live=bl, block_offset=lo, scale=sc)
        merged = osm.merge_partials(part, part_pgd)

        # ---- cross-shard reduction (Alg. 1 across devices) ------------
        m_loc, l_loc, o_loc = merged.m, merged.l, merged.o
        m_star = jax.lax.pmax(m_loc, axis)
        m_star_safe = jnp.where(jnp.isfinite(m_star), m_star, 0.0)
        w = jnp.where(jnp.isfinite(m_loc),
                      jnp.exp(m_loc - m_star_safe), 0.0)     # (B, H)
        o = jax.lax.psum(w[..., None] * o_loc, axis)
        l = jax.lax.psum(w * l_loc, axis)
        inv_l = 1.0 / jnp.maximum(l, 1e-30)
        out = (o * inv_l[..., None]).astype(q.dtype)

        # ---- union mass on absolute coordinates, from global (m*, l) --
        mg = m_star_safe.reshape(B, Hkv, rep)
        il = inv_l.reshape(B, Hkv, rep)[..., None]
        inside = (bt >= lo) & (bt < lo + NB_loc)
        pgd_loc = pgd & jnp.repeat(inside, bs, axis=1)
        bt_loc = jnp.where(inside, bt - lo, 0)
        gk = paged_gather_logical(pk, bt_loc)       # (B, Hkv, Smax, d)
        s_pool = ops._grouped_scores(q, gk, sc)

        def probs(s, mask):
            s = jnp.where(mask[:, None, None, :], s, -jnp.inf)
            p = jnp.exp(s - mg[..., None]) * il
            return jnp.where(jnp.isfinite(s), p, 0.0)

        ph = jnp.mean(probs(s_ring, hot_ring), axis=(1, 2))  # (B, W_loc)
        pp = jnp.mean(probs(s_pool, pgd_loc), axis=(1, 2))   # (B, Smax)
        bidx = jnp.arange(B)[:, None]
        scatter_idx = jnp.clip(ring_pos, 0, Smax - 1)
        mass = jax.lax.psum(
            pp.at[bidx, scatter_idx].add(jnp.where(hot_ring, ph, 0.0)),
            axis)
        hot_eff = jax.lax.pmax(
            jnp.zeros((B, Smax), jnp.int32).at[bidx, scatter_idx].max(
                hot_ring.astype(jnp.int32)), axis).astype(bool)
        n_live = jnp.sum(hot_eff | pgd, axis=-1,
                         keepdims=True).astype(jnp.float32)
        return out, mass * n_live

    sharded = jax.shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(), P(None, None, axis, None), P(None, None, axis, None),
                  P(axis), P(axis), P(), P(), P(), P(), P()),
        out_specs=(P(), P()),
        check_vma=False,
    )

    def decode_attn_fn(q, k_cache, v_cache, pk, pv, kv_lens):
        return sharded(q, k_cache, v_cache, pk, pv, block_table,
                       block_live, hot_mask, paged_mask, kv_lens)

    return decode_attn_fn


def make_gather_based_decode_attn(mesh: Mesh, *, axis: str = "model",
                                  dp=None):
    """The L-PIM / request-level baseline (paper §3.3.1 C1): all-gather the
    KV shards to every device, then attend locally. Same numerics, O(S)
    collective bytes — kept as the ablation/benchmark counterpart."""

    def local_fn(q, k, v, kv_lens):
        k_full = jax.lax.all_gather(k, axis, axis=2, tiled=True)
        v_full = jax.lax.all_gather(v, axis, axis=2, tiled=True)
        from repro.models.attention import dense_decode_attn
        return dense_decode_attn(q, k_full, v_full, kv_lens)

    return jax.shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(dp), P(dp, None, axis, None), P(dp, None, axis, None),
                  P(dp)),
        out_specs=(P(dp), P(dp, None)),
        check_vma=False,
    )
