"""Tests for PAM KV-centric management: importance EMA (eq.7-8),
Algorithm 2 scheduling invariants, intra-device mapping balance (§6.1),
and PAM-interface layout transforms (§6.2)."""

import jax
import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st  # hypothesis, or skip-stub fallback

from repro.core import importance as imp
from repro.core import mapping, pam_interface, scheduling, tiers

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------- importance
def test_importance_ema_formula():
    I_prev = jnp.array([0.5, 0.0, 1.0])
    S = jnp.array([1.0, 1.0, 0.0])
    out = imp.update_importance(I_prev, S, lam=0.6)
    np.testing.assert_allclose(np.asarray(out), [0.8, 0.6, 0.4], rtol=1e-6)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(4, 64))
def test_importance_ema_bounded(seed, n):
    """If step scores are in [0, B], importance stays in [0, B]."""
    key = jax.random.PRNGKey(seed)
    I = jax.random.uniform(key, (n,))
    for i in range(5):
        S = jax.random.uniform(jax.random.fold_in(key, i), (n,)) * 2.0
        I = imp.update_importance(I, S)
    assert float(jnp.min(I)) >= 0.0
    assert float(jnp.max(I)) <= 2.0 + 1e-6


def test_tier_importance_score_means():
    impv = jnp.array([1.0, 2.0, 3.0, 4.0, 100.0])
    tier = jnp.array([0, 0, 1, 2, 2])
    valid = jnp.array([True, True, True, True, False])
    out = imp.tier_importance_score(impv, tier, 3, valid)
    np.testing.assert_allclose(np.asarray(out), [1.5, 3.0, 4.0], rtol=1e-6)


# ---------------------------------------------------------------- scheduling
def _rand_state(seed, n):
    key = jax.random.PRNGKey(seed)
    impv = jax.random.uniform(jax.random.fold_in(key, 0), (n,))
    tier = jax.random.randint(jax.random.fold_in(key, 1), (n,), 0, 3)
    valid = jax.random.uniform(jax.random.fold_in(key, 2), (n,)) < 0.9
    return impv, tier.astype(jnp.int32), valid


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(12, 96))
def test_schedule_preserves_tier_counts(seed, n):
    """Alg. 2 only SWAPS tokens — per-tier populations are invariant
    (capacity safety: no tier can overflow from scheduling)."""
    impv, tier, valid = _rand_state(seed, n)
    cfg = scheduling.ScheduleConfig(x=4.0, y=2.0, max_swaps=16)
    new_tier, moved, swaps = scheduling.schedule_kv(impv, tier, valid, cfg)
    for t in range(3):
        before = int(jnp.sum((tier == t) & valid))
        after = int(jnp.sum((new_tier == t) & valid))
        assert before == after


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(12, 96))
def test_schedule_improves_ratio_error(seed, n):
    impv, tier, valid = _rand_state(seed, n)
    cfg = scheduling.ScheduleConfig(x=4.0, y=2.0, max_swaps=16)
    before = float(scheduling.ratio_error(impv, tier, valid, cfg))
    new_tier, moved, swaps = scheduling.schedule_kv(impv, tier, valid, cfg)
    after = float(scheduling.ratio_error(impv, new_tier, valid, cfg))
    assert after <= before + 1e-5


def test_schedule_bounded_movement():
    impv, tier, valid = _rand_state(3, 256)
    cfg = scheduling.ScheduleConfig(x=8.0, y=3.0, max_swaps=8)
    new_tier, moved, swaps = scheduling.schedule_kv(impv, tier, valid, cfg)
    assert int(swaps) <= 2 * cfg.max_swaps          # both phases bounded
    assert int(jnp.sum(moved)) <= 4 * cfg.max_swaps  # 2 tokens per swap


def test_schedule_empty_tier_is_safe():
    """Capacity-zero tiers (a tier holding NO tokens) must not produce
    NaNs, phantom swaps into the empty tier, or count changes — the
    cluster balancer leans on schedule_kv under skewed occupancy."""
    n = 24
    impv = jnp.linspace(0.1, 1.0, n)
    valid = jnp.ones((n,), bool)
    for empty in (0, 1, 2):
        tier = jnp.where(jnp.arange(n) % 2 == 0, (empty + 1) % 3,
                         (empty + 2) % 3).astype(jnp.int32)
        cfg = scheduling.ScheduleConfig(x=4.0, y=2.0, max_swaps=8)
        new_tier, moved, swaps = scheduling.schedule_kv(impv, tier, valid,
                                                        cfg)
        assert not bool(jnp.any(new_tier == empty))   # stays empty
        err = scheduling.ratio_error(impv, new_tier, valid, cfg)
        assert bool(jnp.isfinite(err))
        for t in range(3):
            assert int(jnp.sum((new_tier == t) & valid)) == \
                int(jnp.sum((tier == t) & valid))


def test_schedule_all_invalid_is_noop():
    n = 16
    impv = jnp.zeros((n,))
    tier = jnp.zeros((n,), jnp.int32)
    valid = jnp.zeros((n,), bool)
    new_tier, moved, swaps = scheduling.schedule_kv(
        impv, tier, valid, scheduling.ScheduleConfig(max_swaps=8))
    assert int(swaps) == 0
    assert not bool(jnp.any(moved))
    np.testing.assert_array_equal(np.asarray(new_tier), np.asarray(tier))


def test_schedule_all_equal_importance_makes_no_swaps():
    """Ties everywhere: no swap is importance-improving (strict >), so
    Alg. 2 terminates immediately instead of cycling equal tokens."""
    n = 30
    impv = jnp.full((n,), 0.5)
    tier = (jnp.arange(n) % 3).astype(jnp.int32)
    valid = jnp.ones((n,), bool)
    new_tier, moved, swaps = scheduling.schedule_kv(
        impv, tier, valid, scheduling.ScheduleConfig(x=8.0, y=3.0,
                                                     max_swaps=16))
    assert int(swaps) == 0
    np.testing.assert_array_equal(np.asarray(new_tier), np.asarray(tier))


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(8, 64),
       x=st.floats(1.0, 16.0), y=st.floats(0.5, 8.0))
def test_ratio_error_monotone_under_schedule_kv(seed, n, x, y):
    """ratio_error never increases under schedule_kv, for arbitrary
    targets — including extreme x/y and degenerate occupancies."""
    impv, tier, valid = _rand_state(seed, n)
    cfg = scheduling.ScheduleConfig(x=float(x), y=float(y), max_swaps=12)
    before = float(scheduling.ratio_error(impv, tier, valid, cfg))
    new_tier, _, _ = scheduling.schedule_kv(impv, tier, valid, cfg)
    after = float(scheduling.ratio_error(impv, new_tier, valid, cfg))
    assert after <= before + 1e-4


def test_schedule_promotes_hot_tokens():
    """A very important token stuck on SSD gets promoted."""
    n = 32
    impv = jnp.full((n,), 0.1).at[5].set(10.0)
    tier = jnp.zeros((n,), jnp.int32)
    tier = tier.at[jnp.arange(16, 32)].set(2)   # half the tokens on SSD
    tier = tier.at[5].set(2)                    # hot token stranded on SSD
    tier = tier.at[0].set(1)                    # one DDR token
    valid = jnp.ones((n,), bool)
    cfg = scheduling.ScheduleConfig(x=8.0, y=3.0, max_swaps=16)
    new_tier, moved, _ = scheduling.schedule_kv(impv, tier, valid, cfg)
    assert int(new_tier[5]) != 2  # escaped SSD


# ------------------------------------------------------------------- mapping
@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(16, 128),
       g=st.sampled_from([2, 4, 8]))
def test_balanced_assign_greedy_bound(seed, n, g):
    """LPT greedy guarantee: max group load <= mean load + max item."""
    key = jax.random.PRNGKey(seed)
    freq = jax.random.exponential(key, (n,))
    valid = jnp.ones((n,), bool)
    assign = mapping.greedy_balanced_assign(freq, valid, g)
    assert assign.shape == (n,)
    assert int(jnp.max(assign)) < g
    loads = mapping.group_loads(freq, assign, valid, g)
    bound = float(jnp.mean(loads) + jnp.max(freq))
    assert float(jnp.max(loads)) <= bound + 1e-5


def test_balanced_assign_beats_naive_on_skew():
    """Adversarial skew (few huge tokens): greedy balances, contiguous
    round-robin-by-position does not."""
    n, g = 64, 4
    freq = jnp.ones((n,)).at[:8].set(50.0)   # 8 hot tokens up front
    valid = jnp.ones((n,), bool)
    assign = mapping.greedy_balanced_assign(freq, valid, g)
    bal = float(mapping.imbalance(freq, assign, valid, g))
    naive = (jnp.arange(n, dtype=jnp.int32) // (n // g))  # contiguous split
    naive_bal = float(mapping.imbalance(freq, naive, valid, g))
    assert bal < naive_bal
    assert bal < 1.1


def test_activation_window_tracking():
    n, w = 8, 10
    fw = jnp.zeros((w, n), jnp.uint8)
    for step in range(13):
        act = jnp.arange(n) % 2 == (step % 2)
        fw = mapping.update_activation_freq(fw, act, jnp.int32(step), window=w)
    counts = mapping.windowed_frequency(fw)
    assert counts.shape == (n,)
    np.testing.assert_array_equal(np.asarray(counts),
                                  [5, 5, 5, 5, 5, 5, 5, 5])


# ------------------------------------------------------------- PAM interface
def test_paged_dense_roundtrip():
    key = jax.random.PRNGKey(0)
    nblocks, block, H, d = 6, 4, 2, 8
    pool = jax.random.normal(key, (nblocks, block, H, d))
    table = jnp.array([3, 0, 5])
    dense = pam_interface.paged_to_dense(pool, table, block)
    assert dense.shape == (12, H, d)
    pool2 = pam_interface.dense_to_paged(dense, jnp.zeros_like(pool), table,
                                         block)
    np.testing.assert_allclose(np.asarray(pool2[table]),
                               np.asarray(pool[table]))


def test_migration_plan_and_apply():
    key = jax.random.PRNGKey(2)
    H, d = 2, 4
    src = jax.random.normal(key, (16, H, d))
    dst = jnp.zeros((8, H, d))
    slot_of_token = jnp.arange(16, dtype=jnp.int32)
    moved = jnp.zeros((16,), bool).at[jnp.array([3, 9, 14])].set(True)
    free = jnp.array([1, 4, 6, 7], dtype=jnp.int32)
    plan = pam_interface.make_migration_plan(moved, slot_of_token, free)
    assert int(plan.count) == 3
    out = pam_interface.apply_migration(src, dst, plan, slot_of_token)
    np.testing.assert_allclose(np.asarray(out[1]), np.asarray(src[3]))
    np.testing.assert_allclose(np.asarray(out[4]), np.asarray(src[9]))
    np.testing.assert_allclose(np.asarray(out[6]), np.asarray(src[14]))
    np.testing.assert_allclose(np.asarray(out[7]), 0.0)  # unused slot


def test_bank_interleave_layout():
    n, G, cap = 10, 2, 8
    dense = jnp.arange(n, dtype=jnp.float32)[:, None, None] * jnp.ones((n, 1, 1))
    assign = jnp.array([0, 1] * 5, dtype=jnp.int32)
    out, slot = pam_interface.bank_interleave(dense, assign, G, cap)
    assert out.shape == (G, cap, 1, 1)
    np.testing.assert_allclose(np.asarray(out[0, :5, 0, 0]), [0, 2, 4, 6, 8])
    np.testing.assert_allclose(np.asarray(out[1, :5, 0, 0]), [1, 3, 5, 7, 9])


# ------------------------------------------------------------ tier placement
def test_initial_placement_recency():
    st_ = tiers.initial_placement(num_tokens=20, max_tokens=32,
                                  tier_capacity_tokens=[4, 8, 100])
    tier = np.asarray(st_.tier_of_token)
    valid = np.asarray(st_.valid)
    assert valid.sum() == 20
    # newest 4 tokens hot, next 8 warm, rest cold
    assert (tier[16:20] == tiers.HOT).all()
    assert (tier[8:16] == tiers.WARM).all()
    assert (tier[:8] == tiers.COLD).all()
