"""Shared fixtures and builders for the test suite (PR 7).

The expensive per-module setup every engine test repeats is building a
reduced model config and initialising its params; ``build_model`` caches
that per ``(arch, seed)`` for the whole pytest process, so modules (and
the fixtures below) share one copy of the deterministic weights instead
of re-deriving them at import time. ``make_pam`` / ``make_engine`` /
``make_requests`` are the common factories — callers pass their policy
numbers explicitly because twin-exactness tests depend on the exact PAM
policy, which must therefore never drift behind a default change.
"""

import jax

jax.config.update("jax_platform_name", "cpu")

import numpy as np  # noqa: E402
import pytest  # noqa: E402

from repro.models import transformer as tf  # noqa: E402
from repro.models.config import get_config, reduced  # noqa: E402
from repro.serving import (EngineSpec, PAMManagerConfig,  # noqa: E402
                           Request, ServingConfig)

_MODELS: dict = {}


def build_model(arch="qwen3-0.6b", seed=0):
    """(cfg, params) for a reduced ``arch``, cached per (arch, seed)
    across the whole pytest process."""
    key = (arch, seed)
    if key not in _MODELS:
        cfg = reduced(get_config(arch))
        _MODELS[key] = (cfg,
                        tf.init_params(cfg, jax.random.PRNGKey(seed)))
    return _MODELS[key]


def make_pam(max_len=64, hot=8, warm=16, compression=4, recency_window=4,
             schedule_interval=2, **kw):
    """PAMManagerConfig with the test suite's spelled-out policy knobs."""
    return PAMManagerConfig(max_tokens=max_len, hot_capacity=hot,
                            warm_capacity=warm, compression=compression,
                            recency_window=recency_window,
                            schedule_interval=schedule_interval, **kw)


def make_engine(cfg, params, *, pam=None, name="dev", latency=None,
                **scfg_kw):
    """ServingEngine from explicit serving-config kwargs. ``pam`` is a
    ready PAMManagerConfig (or None for the dense baseline)."""
    scfg = ServingConfig(pam=pam, **scfg_kw)
    return EngineSpec(model=cfg, serving=scfg,
                      name=name).build(params, latency_model=latency)


def make_requests(n, vocab, plen=16, max_new=12, seed=0, arrivals=False,
                  first_id=0):
    """n deterministic requests with rng(seed) prompts. Arrival times
    (Poisson, 1ms mean gap) are only drawn when asked for, so the prompt
    stream for a given seed is identical either way."""
    rng = np.random.default_rng(seed)
    t = 0.0
    out = []
    for i in range(n):
        if arrivals:
            t += float(rng.exponential(0.001))
        out.append(Request(id=first_id + i,
                           prompt=rng.integers(0, vocab, plen),
                           max_new_tokens=max_new,
                           arrival=t if arrivals else 0.0))
    return out


@pytest.fixture(scope="session")
def qwen_model():
    """Process-cached reduced qwen3-0.6b (cfg, params) — the default
    engine-test model."""
    return build_model("qwen3-0.6b")


@pytest.fixture(scope="session")
def llama_model():
    """Process-cached reduced pam-llama-7b (cfg, params) — the paper's
    headline GQA config, used by the ring-buffer suite."""
    return build_model("pam-llama-7b")
